"""Per-architecture smoke tests: reduced config, one forward + decode on CPU.

Asserts output shapes, finiteness, and (for decode-capable archs) that
incremental decode agrees with teacher-forced full-sequence logits.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_reduced
from repro.models import transformer as T
from repro.models.params import tree_materialize, tree_num_params


def _make(arch):
    cfg = get_reduced(arch)
    defs = T.model_defs(cfg)
    params = tree_materialize(defs, jax.random.PRNGKey(0), cfg.param_dtype)
    return cfg, params


def _inputs(cfg, batch=2, seq=16):
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)
    kwargs = {}
    if cfg.family == "encdec":
        kwargs["enc_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (batch, cfg.encoder_len, cfg.d_model)
        )
    return tokens, kwargs


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg, params = _make(arch)
    tokens, kwargs = _inputs(cfg)
    logits = T.forward(cfg, params, tokens, **kwargs)
    assert logits.shape == (*tokens.shape, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step_no_nans(arch):
    cfg, params = _make(arch)
    tokens, kwargs = _inputs(cfg)
    labels = jnp.roll(tokens, -1, axis=1)

    def loss_fn(p):
        logits = T.forward(cfg, p, tokens, **kwargs)
        lp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(lp, labels[..., None], axis=-1)
        return -ll.mean()

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), f"{arch}: NaN grads"
    # sgd step changes the loss
    params2 = jax.tree_util.tree_map(lambda p, g: p - 0.1 * g.astype(p.dtype),
                                     params, grads)
    loss2 = loss_fn(params2)
    assert bool(jnp.isfinite(loss2))
    assert float(loss2) != float(loss)


def _pin_jnp(cfg):
    """Decode ignores attention_kernel (the cache path is always the
    in-layer einsum), so decode-vs-forward comparisons pin forward to the
    same 'jnp' path — keeping the assertion about CACHE correctness rather
    than f32-vs-bf16 attention accumulation (the registry oracle keeps
    attention in f32; under the 'auto' default that drift is legitimate)."""
    import dataclasses

    return dataclasses.replace(cfg, attention_kernel="jnp")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_teacher_forcing(arch):
    cfg, params = _make(arch)
    cfg = _pin_jnp(cfg)
    batch, seq = 2, 8
    tokens, kwargs = _inputs(cfg, batch, seq)
    full_logits = T.forward(cfg, params, tokens, **kwargs)

    cache = T.init_cache(cfg, batch, max_len=seq + 4)
    if cfg.family == "encdec":
        cache["cross"] = T.encode_cross_cache(
            cfg, params, kwargs["enc_embeds"], batch
        )
    step_logits = []
    for t in range(seq):
        cache, logit = T.decode_step(cfg, params, tokens[:, t : t + 1], cache)
        step_logits.append(logit)
    got = jnp.stack(step_logits, axis=1)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(full_logits), rtol=2e-2, atol=2e-2,
    )


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode(arch):
    """Prefill 6 tokens at once, decode 2 more; equals token-by-token."""
    cfg, params = _make(arch)
    cfg = _pin_jnp(cfg)
    batch, seq = 1, 8
    tokens, kwargs = _inputs(cfg, batch, seq)

    cache = T.init_cache(cfg, batch, max_len=seq)
    if cfg.family == "encdec":
        cache["cross"] = T.encode_cross_cache(
            cfg, params, kwargs["enc_embeds"], batch
        )
    cache, logits_p = T.decode_step(cfg, params, tokens[:, :6], cache)
    cache, l6 = T.decode_step(cfg, params, tokens[:, 6:7], cache)
    cache, l7 = T.decode_step(cfg, params, tokens[:, 7:8], cache)

    full = T.forward(cfg, params, tokens, **kwargs)
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(full[:, 5]),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(l6), np.asarray(full[:, 6]),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(l7), np.asarray(full[:, 7]),
                               rtol=2e-2, atol=2e-2)


def test_param_count_analytic_close_to_actual():
    for arch in ARCH_IDS:
        cfg = get_reduced(arch)
        defs = T.model_defs(cfg)
        actual = tree_num_params(defs)
        analytic = cfg.param_count()
        # analytic formula ignores norm scales etc. — within 10%
        assert abs(actual - analytic) / actual < 0.15, (
            arch, actual, analytic
        )


@pytest.mark.parametrize("arch", ["minitron_8b", "whisper_small"])
def test_attention_kernel_routing_matches_jnp(arch):
    """cfg.attention_kernel routes full-seq self-attention through the
    kernels/ops.py registry; 'off' (jnp oracle) and 'interpret' (Pallas
    interpreter) must match the in-layer einsum path."""
    import dataclasses

    cfg, params = _make(arch)
    tokens, kwargs = _inputs(cfg, batch=1, seq=12)
    # at f32 compute dtype the registry's oracle path and the in-layer
    # einsum path are the same math in the same dtype: exact agreement
    # (bf16 differs legitimately — the kernel path keeps attention in f32)
    # base must be the in-layer einsum EXPLICITLY: the config default is
    # 'auto' now, which on CPU already resolves to the registry oracle
    cfg32 = dataclasses.replace(
        cfg, compute_dtype=jnp.float32, attention_kernel="jnp"
    )
    base = T.forward(cfg32, params, tokens, **kwargs)
    ref = T.forward(
        dataclasses.replace(cfg32, attention_kernel="off"), params, tokens,
        **kwargs,
    )
    np.testing.assert_allclose(
        np.asarray(ref), np.asarray(base), rtol=1e-6, atol=1e-6
    )
    # oracle vs the Pallas interpreter through the same routing
    interp = T.forward(
        dataclasses.replace(cfg32, attention_kernel="interpret"), params,
        tokens, **kwargs,
    )
    np.testing.assert_allclose(
        np.asarray(interp), np.asarray(ref), rtol=1e-4, atol=1e-4
    )

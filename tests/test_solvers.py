"""The solver registry: one `solve()` entrypoint with typed capabilities.

Registry semantics — the registered methods x {dense, sparse-where-
supported} dispatch through `core.solvers.solve`, `available_solvers()`
exposes a typed `SolverCapabilities` record per method, unknown methods /
comm backends / hyperparameters fail loudly (unsupported combinations as
`CapabilityError`), and the SolveResult schema is uniform. The deprecated
shim parity pins live in `tests/test_deprecated_shims.py`.
"""
import numpy as np
import pytest

from repro.core import mixing, reference
from repro.core.dsba import draw_indices
from repro.core.operators import OperatorSpec
from repro.core.solvers import (
    CapabilityError,
    Problem,
    SolverCapabilities,
    available_solvers,
    get_solver,
    graph_from_mixing,
    make_problem,
    register_solver,
    solve,
)
from repro.data.synthetic import make_classification, make_regression

STEPS = 24
REC = 8
GRAPHS = ["ring", "erdos_renyi"]
TASKS = ["ridge", "logistic", "auc"]


def _problem(task, gname="erdos_renyi", n_nodes=5, q=6, d=16, k=4, lam=1e-2,
             seed=0):
    if task == "ridge":
        data = make_regression(n_nodes, q, d, k=k, seed=seed)
    elif task == "logistic":
        data = make_classification(n_nodes, q, d, k=k, seed=seed)
    else:
        data = make_classification(n_nodes, q, d, k=k, positive_ratio=0.3,
                                   seed=seed)
    if gname == "ring":
        graph = mixing.ring_graph(n_nodes)
    else:
        graph = mixing.erdos_renyi_graph(n_nodes, 0.4, seed=1)
    return make_problem(task, data, graph, lam=lam)


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------


def test_registry_exposes_capability_records():
    avail = available_solvers()
    assert set(avail) == {
        "dsba", "dsa", "extra", "dlm", "ssda", "mudag", "sliding", "dsgda",
        "personal",
    }
    assert all(isinstance(c, SolverCapabilities) for c in avail.values())
    # sparse comm: the stochastic family only (the paper's relay broadcasts
    # per-sample deltas; everything else exchanges dense vectors by nature)
    assert {n: c.supports_sparse_comm for n, c in avail.items()} == {
        "dsba": True, "dsa": True, "extra": False, "dlm": False,
        "ssda": False, "mudag": False, "sliding": False, "dsgda": False,
        "personal": False,
    }
    # every registered step is written against comm.matvec/comm.local
    # (personal is the one dense-only entry: its fixed point is
    # non-consensus, so per-device leading-axis sharding does not apply)
    assert all(c.supports_sharded for n, c in avail.items()
               if n != "personal")
    assert not avail["personal"].supports_sharded
    # the dynamic-network axes (PR 8): schedules for the W-independent
    # fixed points, churn only where elastic remap + reanchor is sound,
    # per-node lam only for the resolvent/forward families that take it
    assert {n: c.supports_schedule for n, c in avail.items()} == {
        "dsba": True, "dsa": True, "extra": False, "dlm": False,
        "ssda": False, "mudag": True, "sliding": True, "dsgda": True,
        "personal": True,
    }
    # churn covers the stochastic family AND the tracking family (whose
    # reanchor zeroes trackers and rewinds t so the t==0 branch re-seeds
    # from the surviving membership — tests/test_faults.py)
    assert {n for n, c in avail.items() if c.supports_churn} == {
        "dsba", "dsa", "mudag", "sliding", "dsgda"
    }
    # stragglers: dense-only delivery buffers; mudag/sliding run their
    # gossip matvecs inside traced fori_loops where buffer writes can't
    # live, so they type out of the straggler axis (link faults stay legal)
    assert {n for n, c in avail.items() if c.supports_stragglers} == {
        "dsba", "dsa", "extra", "dlm", "ssda", "dsgda", "personal"
    }
    assert {n for n, c in avail.items() if c.supports_per_node_lam} == {
        "dsba", "dsa", "personal"
    }
    # the problem-family axis: the paper's scalar-table machinery covers
    # every linear-predictor family incl. the bilinear saddle; descent-only
    # methods are minimization-only; descent-ascent is saddle-only
    assert avail["dsba"].problem_families == (
        "ridge", "logistic", "auc", "bilinear"
    )
    assert avail["mudag"].problem_families == ("ridge", "logistic")
    assert avail["sliding"].problem_families == ("ridge", "logistic")
    assert avail["ssda"].problem_families == ("ridge", "logistic")
    assert avail["dsgda"].problem_families == ("auc", "bilinear")
    # derived views used by solve()'s capability gate
    assert avail["mudag"].comm_backends() == ("dense", "sharded")
    assert avail["dsba"].comm_backends() == ("dense", "sparse", "sharded")
    assert avail["dsba"].supports("sparse", "bilinear")
    assert not avail["mudag"].supports("sparse", "ridge")
    assert not avail["dsgda"].supports("dense", "ridge")


def test_unknown_method_comm_and_hyperparams_fail_loudly():
    problem = _problem("ridge")
    with pytest.raises(KeyError, match="unknown method"):
        solve(problem, "sgd", steps=2)
    with pytest.raises(ValueError, match="comm backend"):
        solve(problem, "dsba", comm="pigeon", steps=2)
    with pytest.raises(TypeError, match="unknown hyperparameters"):
        solve(problem, "dsba", steps=2, learning_rate=0.1)
    with pytest.raises(ValueError, match="comm_options"):
        solve(problem, "dsba", comm="dense", steps=2,
              comm_options={"verify": True})


def test_register_rejects_duplicate_names():
    with pytest.raises(ValueError, match="already registered"):
        register_solver(get_solver("dsba"))


def test_problem_defaults_and_z_star_cache():
    problem = _problem("ridge")
    # default mixing is the paper's Laplacian weights on the graph
    np.testing.assert_allclose(
        problem.w, mixing.laplacian_mixing(problem.graph), atol=1e-15
    )
    z1 = problem.solve_star()
    assert problem.solve_star() is z1  # cached, not recomputed
    np.testing.assert_allclose(
        z1, reference.solve_root(problem.spec, problem.data, problem.lam),
        atol=1e-12,
    )


def test_graph_from_mixing_roundtrip():
    graph = mixing.erdos_renyi_graph(7, 0.4, seed=3)
    w = mixing.laplacian_mixing(graph)
    assert sorted(graph_from_mixing(w).edges) == sorted(graph.edges)
    wm = mixing.metropolis_mixing(graph)
    assert sorted(graph_from_mixing(wm).edges) == sorted(graph.edges)


def test_mismatched_problem_shapes_rejected():
    data = make_regression(5, 6, 16, k=4, seed=0)
    graph = mixing.ring_graph(4)
    with pytest.raises(ValueError, match="nodes"):
        Problem(spec=OperatorSpec("ridge"), data=data, graph=graph)


def test_record_points_cover_ragged_tail():
    problem = _problem("ridge")
    res = solve(problem, "dsba", steps=25, record_every=10, alpha=0.3)
    assert list(res.iters) == [10, 20, 25]
    assert res.consensus.shape == (3,)
    assert res.doubles_received.shape == (3, 5)


def test_short_or_misshaped_indices_rejected():
    """A too-short index stream must fail loudly on BOTH comm backends (the
    dense scan would otherwise run empty chunks and silently report metrics
    and communication cost for iterations that never happened)."""
    problem = _problem("ridge")
    short = draw_indices(10, 5, 6, seed=0)
    with pytest.raises(ValueError, match="indices"):
        solve(problem, "dsba", steps=40, indices=short)
    with pytest.raises(ValueError, match="indices"):
        solve(problem, "dsba", comm="sparse", steps=40, indices=short)
    wrong_n = draw_indices(40, 4, 6, seed=0)
    with pytest.raises(ValueError, match="indices"):
        solve(problem, "extra", steps=40, indices=wrong_n)


def test_solve_replays_identically_from_seed_and_indices():
    problem = _problem("ridge")
    a = solve(problem, "dsba", steps=STEPS, record_every=REC, seed=11,
              alpha=0.3)
    b = solve(problem, "dsba", steps=STEPS, record_every=REC, seed=11,
              alpha=0.3)
    c = solve(problem, "dsba", steps=STEPS, record_every=REC, seed=12,
              alpha=0.3)
    assert np.array_equal(a.z, b.z)
    assert not np.array_equal(a.z, c.z)


# ---------------------------------------------------------------------------
# typed capability failures: CapabilityError names the combination
# ---------------------------------------------------------------------------


def test_ssda_rejects_auc_tail_as_capability_error():
    """The paper: SSDA needs grad f* and does not apply to the AUC saddle.
    Pre-PR-7 this surfaced as a factory-time NotImplementedError; now it is
    a typed CapabilityError (a ValueError) naming the combination."""
    problem = _problem("auc")
    with pytest.raises(CapabilityError, match="ssda.*auc") as ei:
        solve(problem, "ssda", steps=2)
    assert (ei.value.method, ei.value.comm, ei.value.family) == (
        "ssda", "dense", "auc"
    )
    assert isinstance(ei.value, ValueError)


def test_capability_error_not_silent_dense_fallback():
    """mudag/sliding have no sparse backend: asking for comm='sparse' must
    be a typed error naming (method, comm, family) — never a dense run."""
    problem = _problem("ridge")
    for method in ("mudag", "sliding"):
        with pytest.raises(CapabilityError, match=f"{method}.*sparse"):
            solve(problem, method, comm="sparse", steps=2)
    with pytest.raises(CapabilityError, match="dsgda.*ridge"):
        solve(problem, "dsgda", steps=2)

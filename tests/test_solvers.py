"""The solver registry: one `solve()` entrypoint, shims pinned trace-identical.

Two claims:

1. Registry semantics — five methods x {dense, sparse-where-supported}
   dispatch through `core.solvers.solve`, unknown methods / comm backends /
   hyperparameters fail loudly, and the SolveResult schema is uniform.
2. Shim parity — the deprecated wrappers (`core.dsba.run`,
   `core.baselines.run_*`) reproduce `solve(method=..., comm="dense")`
   exactly: bit-equal snapshot traces for dsba/dsa, <=1e-12 across
   ridge/logistic/auc on ring + Erdős–Rényi graphs for the baselines.
"""
import warnings

import numpy as np
import pytest

from repro.core import deprecation, mixing, reference
from repro.core.baselines import run_dlm, run_extra, run_ssda
from repro.core.dsba import DSBAConfig, draw_indices
from repro.core.dsba import run as legacy_run
from repro.core.operators import OperatorSpec
from repro.core.solvers import (
    Problem,
    available_solvers,
    get_solver,
    graph_from_mixing,
    make_problem,
    register_solver,
    solve,
)
from repro.data.synthetic import make_classification, make_regression

STEPS = 24
REC = 8
GRAPHS = ["ring", "erdos_renyi"]
TASKS = ["ridge", "logistic", "auc"]


@pytest.fixture
def fresh_deprecations():
    """Shim warnings fire once per process; reset so this test sees them."""
    deprecation.reset()
    yield
    deprecation.reset()


def _problem(task, gname="erdos_renyi", n_nodes=5, q=6, d=16, k=4, lam=1e-2,
             seed=0):
    if task == "ridge":
        data = make_regression(n_nodes, q, d, k=k, seed=seed)
    elif task == "logistic":
        data = make_classification(n_nodes, q, d, k=k, seed=seed)
    else:
        data = make_classification(n_nodes, q, d, k=k, positive_ratio=0.3,
                                   seed=seed)
    if gname == "ring":
        graph = mixing.ring_graph(n_nodes)
    else:
        graph = mixing.erdos_renyi_graph(n_nodes, 0.4, seed=1)
    return make_problem(task, data, graph, lam=lam)


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------


def test_registry_has_all_five_methods():
    avail = available_solvers()
    assert set(avail) == {"dsba", "dsa", "extra", "dlm", "ssda"}
    # sparse comm: the stochastic family only (the paper's relay broadcasts
    # per-sample deltas; the deterministic baselines are dense by nature)
    assert avail == {"dsba": True, "dsa": True, "extra": False,
                     "dlm": False, "ssda": False}


def test_unknown_method_comm_and_hyperparams_fail_loudly():
    problem = _problem("ridge")
    with pytest.raises(KeyError, match="unknown method"):
        solve(problem, "sgd", steps=2)
    with pytest.raises(ValueError, match="comm backend"):
        solve(problem, "dsba", comm="pigeon", steps=2)
    with pytest.raises(TypeError, match="unknown hyperparameters"):
        solve(problem, "dsba", steps=2, learning_rate=0.1)
    with pytest.raises(ValueError, match="comm_options"):
        solve(problem, "dsba", comm="dense", steps=2,
              comm_options={"verify": True})


def test_register_rejects_duplicate_names():
    with pytest.raises(ValueError, match="already registered"):
        register_solver(get_solver("dsba"))


def test_problem_defaults_and_z_star_cache():
    problem = _problem("ridge")
    # default mixing is the paper's Laplacian weights on the graph
    np.testing.assert_allclose(
        problem.w, mixing.laplacian_mixing(problem.graph), atol=1e-15
    )
    z1 = problem.solve_star()
    assert problem.solve_star() is z1  # cached, not recomputed
    np.testing.assert_allclose(
        z1, reference.solve_root(problem.spec, problem.data, problem.lam),
        atol=1e-12,
    )


def test_graph_from_mixing_roundtrip():
    graph = mixing.erdos_renyi_graph(7, 0.4, seed=3)
    w = mixing.laplacian_mixing(graph)
    assert sorted(graph_from_mixing(w).edges) == sorted(graph.edges)
    wm = mixing.metropolis_mixing(graph)
    assert sorted(graph_from_mixing(wm).edges) == sorted(graph.edges)


def test_mismatched_problem_shapes_rejected():
    data = make_regression(5, 6, 16, k=4, seed=0)
    graph = mixing.ring_graph(4)
    with pytest.raises(ValueError, match="nodes"):
        Problem(spec=OperatorSpec("ridge"), data=data, graph=graph)


def test_record_points_cover_ragged_tail():
    problem = _problem("ridge")
    res = solve(problem, "dsba", steps=25, record_every=10, alpha=0.3)
    assert list(res.iters) == [10, 20, 25]
    assert res.consensus.shape == (3,)
    assert res.doubles_received.shape == (3, 5)


def test_short_or_misshaped_indices_rejected():
    """A too-short index stream must fail loudly on BOTH comm backends (the
    dense scan would otherwise run empty chunks and silently report metrics
    and communication cost for iterations that never happened)."""
    problem = _problem("ridge")
    short = draw_indices(10, 5, 6, seed=0)
    with pytest.raises(ValueError, match="indices"):
        solve(problem, "dsba", steps=40, indices=short)
    with pytest.raises(ValueError, match="indices"):
        solve(problem, "dsba", comm="sparse", steps=40, indices=short)
    wrong_n = draw_indices(40, 4, 6, seed=0)
    with pytest.raises(ValueError, match="indices"):
        solve(problem, "extra", steps=40, indices=wrong_n)


def test_solve_replays_identically_from_seed_and_indices():
    problem = _problem("ridge")
    a = solve(problem, "dsba", steps=STEPS, record_every=REC, seed=11,
              alpha=0.3)
    b = solve(problem, "dsba", steps=STEPS, record_every=REC, seed=11,
              alpha=0.3)
    c = solve(problem, "dsba", steps=STEPS, record_every=REC, seed=12,
              alpha=0.3)
    assert np.array_equal(a.z, b.z)
    assert not np.array_equal(a.z, c.z)


# ---------------------------------------------------------------------------
# shim parity: dsba/dsa bit-equal, baselines <= 1e-12
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("gname", GRAPHS)
@pytest.mark.parametrize("task", TASKS)
def test_dsba_dsa_shims_bit_identical(task, gname, fresh_deprecations):
    problem = _problem(task, gname)
    n, q = problem.data.n_nodes, problem.data.q
    indices = draw_indices(STEPS, n, q, seed=5)
    for method in ("dsba", "dsa"):
        cfg = DSBAConfig(problem.spec, 0.3, problem.lam, method=method)
        deprecation.reset()
        with pytest.warns(DeprecationWarning):
            legacy = legacy_run(
                cfg, problem.data, problem.w, STEPS, record_every=REC,
                indices=indices, keep_snapshots=True,
            )
        new = solve(problem, method, steps=STEPS, record_every=REC,
                    indices=indices, keep_snapshots=True, alpha=0.3)
        assert np.array_equal(legacy.zs, new.zs), (task, gname, method)
        assert np.array_equal(np.asarray(legacy.state.z), new.z)
        assert (legacy.iters == new.iters).all()


@pytest.mark.parametrize("gname", GRAPHS)
@pytest.mark.parametrize("task", TASKS)
def test_baseline_shims_trace_match(task, gname, fresh_deprecations):
    problem = _problem(task, gname)
    z_star = problem.solve_star()
    data, w, lam = problem.data, problem.w, problem.lam

    deprecation.reset()
    with pytest.warns(DeprecationWarning):
        legacy = run_extra(problem.spec, data, w, alpha=0.2, lam=lam,
                           steps=STEPS, z_star=z_star, record_every=REC)
    new = solve(problem, "extra", steps=STEPS, record_every=REC, alpha=0.2)
    np.testing.assert_allclose(
        np.asarray(legacy.state[0]), new.z, rtol=0, atol=1e-12
    )
    np.testing.assert_allclose(legacy.dist2, new.dist2, rtol=0, atol=1e-12)
    np.testing.assert_allclose(legacy.consensus, new.consensus, rtol=0,
                               atol=1e-12)

    deprecation.reset()
    with pytest.warns(DeprecationWarning):
        legacy = run_dlm(problem.spec, data, problem.graph, c=0.3, beta=1.0,
                         lam=lam, steps=STEPS, z_star=z_star,
                         record_every=REC)
    new = solve(problem, "dlm", steps=STEPS, record_every=REC, c=0.3,
                beta=1.0)
    np.testing.assert_allclose(
        np.asarray(legacy.state[0]), new.z, rtol=0, atol=1e-12
    )
    np.testing.assert_allclose(legacy.dist2, new.dist2, rtol=0, atol=1e-12)

    if task != "auc":  # the paper: SSDA does not apply to the AUC saddle
        deprecation.reset()
        with pytest.warns(DeprecationWarning):
            legacy = run_ssda(problem.spec, data, w, eta=0.05, momentum=0.5,
                              lam=lam, steps=STEPS, z_star=z_star,
                              record_every=REC)
        new = solve(problem, "ssda", steps=STEPS, record_every=REC,
                    eta=0.05, momentum=0.5)
        np.testing.assert_allclose(legacy.dist2, new.dist2, rtol=0,
                                   atol=1e-12)
        np.testing.assert_allclose(legacy.consensus, new.consensus, rtol=0,
                                   atol=1e-12)


def test_ssda_rejects_auc_tail():
    problem = _problem("auc")
    with pytest.raises(NotImplementedError, match="SSDA"):
        solve(problem, "ssda", steps=2)


def test_shims_warn_once_per_process_at_caller(fresh_deprecations):
    """Sweep loops through legacy shims must not spam: one warning per shim
    per process, attributed (stacklevel) to the caller's file."""
    problem = _problem("ridge")
    cfg = DSBAConfig(problem.spec, 0.3, problem.lam, method="dsba")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        for _ in range(3):
            legacy_run(cfg, problem.data, problem.w, 4, record_every=4)
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1
    assert dep[0].filename == __file__

    deprecation.reset()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        for _ in range(3):
            run_extra(problem.spec, problem.data, problem.w, alpha=0.2,
                      lam=problem.lam, steps=4)
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1
    assert dep[0].filename == __file__

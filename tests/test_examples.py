"""Smoke tests for the public examples: each `main()` runs at reduced scale.

The examples are the documented face of the solver API; importing them by
file path (they are scripts, not a package) and running their `main()` at a
few steps under tier-1 means the public surface cannot silently rot when
the core API moves again.
"""
import importlib.util
import pathlib

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _load(name):
    path = ROOT / "examples" / f"{name}.py"
    spec = importlib.util.spec_from_file_location(f"example_{name}", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_quickstart_main(capsys):
    res = _load("quickstart").main(steps=300, record_every=100)
    out = capsys.readouterr().out
    assert "consensus" in out
    assert len(res.iters) == 3
    assert res.dist2[-1] < res.dist2[0]  # it is optimizing


def test_decentralized_ridge_main(capsys):
    results = _load("decentralized_ridge").main(
        ["--passes", "2", "--q", "8", "--d", "64"]
    )
    out = capsys.readouterr().out
    assert set(results) == {"DSBA", "DSA", "EXTRA", "DLM", "SSDA"}
    assert "communication per effective pass" in out
    for _, dist2 in results.values():
        assert len(dist2) == 2 and all(d > 0 for d in dist2)


def test_auc_maximization_main(capsys):
    res = _load("auc_maximization").main(passes=2, record_passes=1)
    out = capsys.readouterr().out
    assert "AUC at the exact saddle point" in out
    assert res.zs is not None and len(res.iters) == 2

"""The capability matrix: solve or CapabilityError, never a third outcome.

Every (registered solver x comm backend x operator family) combination on a
4-node ring either returns a finite SolveResult or raises a typed
``CapabilityError`` that names the combination — in exact agreement with
the ``SolverCapabilities`` record the registry advertises. There is no
third outcome: no silent dense fallback, no NotImplementedError from deep
inside a factory, no partially-populated result.

The dense and sparse backends are exercised here (tier-1, single device);
the sharded leg of the same matrix runs under the forced-8-device tier in
``tests/multidevice/test_sharded_inner.py``.
"""
import functools

import numpy as np
import pytest

from repro.core import mixing
from repro.core.operators import FAMILIES
from repro.core.solvers import (
    CapabilityError,
    available_solvers,
    make_problem,
    solve,
)
from repro.data.synthetic import make_classification, make_regression

N, Q, D, K = 4, 6, 8, 3
METHODS = sorted(available_solvers())
COMMS = ("dense", "sparse")
# registry defaults are tuned for the paper's ridge shapes; the matrix only
# asserts "runs and stays finite", so damp the aggressive ones
HP = {"ssda": dict(eta=1e-3, momentum=0.0),
      "mudag": dict(eta=0.5, momentum=0.5)}


@functools.lru_cache(maxsize=None)
def _problem(family):
    if family in ("ridge", "bilinear"):
        data = make_regression(N, Q, D, k=K, seed=0)
    elif family == "logistic":
        data = make_classification(N, Q, D, k=K, seed=0)
    else:  # auc
        data = make_classification(N, Q, D, k=K, positive_ratio=0.3, seed=0)
    return make_problem(family, data, mixing.ring_graph(N), lam=1e-2)


@pytest.mark.parametrize("comm", COMMS)
@pytest.mark.parametrize("family", FAMILIES)
@pytest.mark.parametrize("method", METHODS)
def test_matrix_solves_or_raises_capability_error(method, family, comm):
    caps = available_solvers()[method]
    problem = _problem(family)
    try:
        res = solve(problem, method, comm=comm, steps=6, record_every=3,
                    seed=0, **HP.get(method, {}))
    except CapabilityError as e:
        # typed refusal: only for combinations the record already excludes,
        # and the error names exactly the (method, comm, family) asked for
        assert not caps.supports(comm, family)
        assert (e.method, e.comm, e.family) == (method, comm, family)
        return
    # any other exception propagates and fails the test: the combination
    # must run iff the capability record says it does
    assert caps.supports(comm, family)
    assert res.method == method and res.comm == comm
    assert res.z.shape == (N, D + problem.spec.tail_dim)
    assert np.isfinite(res.z).all()
    assert np.isfinite(res.dist2).all()


def test_matrix_agrees_with_advertised_support_counts():
    """The record is the ground truth the matrix above is checked against;
    pin its aggregate so a capability silently flipped in a registration
    shows up as a count change here, not as 32 confusing matrix failures."""
    avail = available_solvers()
    supported = sum(
        avail[m].supports(c, f)
        for m in METHODS for c in COMMS for f in FAMILIES
    )
    total = len(METHODS) * len(COMMS) * len(FAMILIES)
    assert total == 64
    # dense: dsba/dsa 4 families each, extra/dlm 3, ssda/mudag/sliding 2,
    # dsgda 2 -> 22; sparse: dsba/dsa only -> 8
    assert supported == 30

"""The capability matrix: solve or CapabilityError, never a third outcome.

Every (registered solver x comm backend x operator family) combination on a
4-node ring either returns a finite SolveResult or raises a typed
``CapabilityError`` that names the combination — in exact agreement with
the ``SolverCapabilities`` record the registry advertises. There is no
third outcome: no silent dense fallback, no NotImplementedError from deep
inside a factory, no partially-populated result.

The dense and sparse backends are exercised here (tier-1, single device);
the sharded leg of the same matrix runs under the forced-8-device tier in
``tests/multidevice/test_sharded_inner.py``.
"""
import functools

import numpy as np
import pytest

from repro.core import mixing
from repro.core.operators import FAMILIES
from repro.core.solvers import (
    CapabilityError,
    available_solvers,
    make_problem,
    solve,
)
from repro.data.synthetic import make_classification, make_regression

N, Q, D, K = 4, 6, 8, 3
METHODS = sorted(available_solvers())
COMMS = ("dense", "sparse")
# registry defaults are tuned for the paper's ridge shapes; the matrix only
# asserts "runs and stays finite", so damp the aggressive ones
HP = {"ssda": dict(eta=1e-3, momentum=0.0),
      "mudag": dict(eta=0.5, momentum=0.5)}


@functools.lru_cache(maxsize=None)
def _problem(family):
    if family in ("ridge", "bilinear"):
        data = make_regression(N, Q, D, k=K, seed=0)
    elif family == "logistic":
        data = make_classification(N, Q, D, k=K, seed=0)
    else:  # auc
        data = make_classification(N, Q, D, k=K, positive_ratio=0.3, seed=0)
    return make_problem(family, data, mixing.ring_graph(N), lam=1e-2)


@pytest.mark.parametrize("comm", COMMS)
@pytest.mark.parametrize("family", FAMILIES)
@pytest.mark.parametrize("method", METHODS)
def test_matrix_solves_or_raises_capability_error(method, family, comm):
    caps = available_solvers()[method]
    problem = _problem(family)
    try:
        res = solve(problem, method, comm=comm, steps=6, record_every=3,
                    seed=0, **HP.get(method, {}))
    except CapabilityError as e:
        # typed refusal: only for combinations the record already excludes,
        # and the error names exactly the (method, comm, family) asked for
        assert not caps.supports(comm, family)
        assert (e.method, e.comm, e.family) == (method, comm, family)
        return
    # any other exception propagates and fails the test: the combination
    # must run iff the capability record says it does
    assert caps.supports(comm, family)
    assert res.method == method and res.comm == comm
    assert res.z.shape == (N, D + problem.spec.tail_dim)
    assert np.isfinite(res.z).all()
    assert np.isfinite(res.dist2).all()


def test_matrix_agrees_with_advertised_support_counts():
    """The record is the ground truth the matrix above is checked against;
    pin its aggregate so a capability silently flipped in a registration
    shows up as a count change here, not as 32 confusing matrix failures."""
    avail = available_solvers()
    supported = sum(
        avail[m].supports(c, f)
        for m in METHODS for c in COMMS for f in FAMILIES
    )
    total = len(METHODS) * len(COMMS) * len(FAMILIES)
    assert total == 72
    # dense: dsba/dsa 4 families each, extra/dlm 3, ssda/mudag/sliding 2,
    # dsgda 2, personal 2 -> 24; sparse: dsba/dsa only -> 8
    assert supported == 32


# ---------------------------------------------------------------------------
# dynamic-network axes: schedule / churn / per-node lam
# ---------------------------------------------------------------------------
# The same no-third-outcome rule covers the dynamic axes: a method that does
# not advertise the capability refuses with a typed CapabilityError naming
# the exact (method, comm, family) triple BEFORE any factory or compile runs
# — never a silent fall-back to the static run.

def _two_ring_schedule():
    import dataclasses

    g = mixing.ring_graph(N)
    g2 = mixing.complete_graph(N)
    return dataclasses.replace(_problem("ridge"), schedule=((0, g), (3, g2)))


def test_schedule_on_unsupporting_method_raises_before_factory():
    problem = _two_ring_schedule()
    for method in METHODS:
        caps = available_solvers()[method]
        if caps.supports_schedule or not caps.supports("dense", "ridge"):
            continue
        with pytest.raises(CapabilityError) as ei:
            solve(problem, method, comm="dense", steps=6, record_every=3,
                  seed=0, **HP.get(method, {}))
        assert (ei.value.method, ei.value.comm, ei.value.family) == (
            method, "dense", "ridge")


def test_churn_on_unsupporting_method_raises_before_factory():
    from repro.core.solvers import ChurnEvent, ChurnPlan

    plan = ChurnPlan((ChurnEvent(at=3, kind="kill", nodes=(3,)),))
    problem = _problem("ridge")
    for method in METHODS:
        caps = available_solvers()[method]
        if caps.supports_churn or not caps.supports("dense", "ridge"):
            continue
        with pytest.raises(CapabilityError) as ei:
            solve(problem, method, comm="dense", steps=6, record_every=3,
                  seed=0, comm_options={"fault_plan": plan},
                  **HP.get(method, {}))
        assert (ei.value.method, ei.value.comm, ei.value.family) == (
            method, "dense", "ridge")


def test_churn_under_sparse_comm_runs():
    """Churn became legal on the sparse backend (the relay's protocol
    tables are re-derived per membership segment and chained via
    ``state0``): a kill on dsba/sparse runs and stays finite. The
    parity-vs-dense pin lives in tests/test_faults.py."""
    from repro.core.solvers import ChurnEvent, ChurnPlan

    plan = ChurnPlan((ChurnEvent(at=3, kind="kill", nodes=(3,)),))
    res = solve(_problem("ridge"), "dsba", comm="sparse", steps=6,
                record_every=3, seed=0, comm_options={"fault_plan": plan})
    assert res.z.shape[0] == N - 1
    assert np.isfinite(res.z).all()
    assert "churn_rows" in res.extras


def test_stragglers_outside_dense_raise():
    """Straggler buffers are a dense-backend feature: the sparse relay's
    reconstruction waves and the sharded ppermute schedule both have no
    last-delivered slot to serve stale values from."""
    from repro.core.solvers import FaultPlan, StragglerSpec

    plan = FaultPlan(straggler=StragglerSpec(p=0.3, max_staleness=2))
    for comm in ("sparse", "sharded"):
        with pytest.raises(CapabilityError) as ei:
            solve(_problem("ridge"), "dsba", comm=comm, steps=6,
                  record_every=3, seed=0,
                  comm_options={"fault_plan": plan})
        assert (ei.value.method, ei.value.comm) == ("dsba", comm)


def test_stragglers_on_unsupporting_method_raise():
    """mudag/sliding advertise supports_stragglers=False (FastMix's
    fori_loop / off-round gating cannot host the delivery buffers):
    typed refusal before any factory runs."""
    from repro.core.solvers import FaultPlan, StragglerSpec

    plan = FaultPlan(straggler=StragglerSpec(p=0.3, max_staleness=2))
    for method in METHODS:
        caps = available_solvers()[method]
        if caps.supports_stragglers or not caps.supports("dense", "ridge"):
            continue
        with pytest.raises(CapabilityError) as ei:
            solve(_problem("ridge"), method, comm="dense", steps=6,
                  record_every=3, seed=0, comm_options={"fault_plan": plan},
                  **HP.get(method, {}))
        assert (ei.value.method, ei.value.comm, ei.value.family) == (
            method, "dense", "ridge")


def test_per_node_lam_outside_dense_raises():
    import dataclasses

    problem = dataclasses.replace(
        _problem("ridge"), lam=np.full(N, 1e-2), z_star=None)
    for comm in ("sparse", "sharded"):
        with pytest.raises(CapabilityError) as ei:
            solve(problem, "dsba", comm=comm, steps=6, record_every=3, seed=0)
        assert (ei.value.method, ei.value.comm) == ("dsba", comm)


def test_per_node_lam_on_unsupporting_method_raises():
    import dataclasses

    problem = dataclasses.replace(
        _problem("ridge"), lam=np.full(N, 1e-2), z_star=None)
    for method in METHODS:
        caps = available_solvers()[method]
        if caps.supports_per_node_lam or not caps.supports("dense", "ridge"):
            continue
        with pytest.raises(CapabilityError) as ei:
            solve(problem, method, comm="dense", steps=6, record_every=3,
                  seed=0, **HP.get(method, {}))
        assert (ei.value.method, ei.value.comm, ei.value.family) == (
            method, "dense", "ridge")

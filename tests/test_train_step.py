"""Train-step substrate: loss decrease, microbatch equivalence, remat."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.optim.adam import AdamConfig, adam_init, adam_update, global_norm
from repro.train.step import (
    TrainConfig, ce_loss, init_train_state, local_grads, train_step,
)


def _cfg(**kw):
    return dataclasses.replace(get_reduced("minitron_8b"), n_layers=2, **kw)


def _batch(cfg, bsz=4, seq=16, seed=0):
    k = jax.random.PRNGKey(seed)
    toks = jax.random.randint(k, (bsz, seq + 1), 0, cfg.vocab_size)
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


def test_loss_decreases_over_steps():
    cfg = _cfg()
    tc = TrainConfig(optimizer=AdamConfig(lr=1e-2, warmup_steps=1))
    state = init_train_state(cfg, tc, jax.random.PRNGKey(0))
    step = jax.jit(lambda s, b: train_step(cfg, tc, s, b))
    losses = []
    for i in range(25):
        state, m = step(state, _batch(cfg, seed=i % 2))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8


def test_microbatch_grads_equal_full_batch():
    # f32 compute for exact accumulation-order comparison (bf16 noise
    # otherwise dominates the tolerance)
    cfg = _cfg(compute_dtype=jnp.float32)
    tc1 = TrainConfig(microbatches=1)
    tc4 = TrainConfig(microbatches=4)
    state = init_train_state(cfg, tc1, jax.random.PRNGKey(0))
    batch = _batch(cfg, bsz=8)
    l1, g1 = local_grads(cfg, tc1, state["params"], batch)
    l4, g4 = local_grads(cfg, tc4, state["params"], batch)
    np.testing.assert_allclose(float(l1), float(l4), rtol=1e-6)
    # f32 accumulation-order noise only (measured ~5e-6 absolute)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-3, atol=2e-5
        ),
        g1, g4,
    )


def test_remat_policies_same_grads():
    tc = TrainConfig()
    batch = None
    grads = {}
    for remat in ("none", "full", "dots"):
        cfg = _cfg(remat=remat)
        state = init_train_state(cfg, tc, jax.random.PRNGKey(0))
        if batch is None:
            batch = _batch(cfg)
        _, g = local_grads(cfg, tc, state["params"], batch)
        grads[remat] = g
    for other in ("full", "dots"):
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-7
            ),
            grads["none"], grads[other],
        )


def test_ce_loss_masked():
    logits = jnp.zeros((1, 4, 8))
    targets = jnp.asarray([[1, 2, 3, 4]])
    mask = jnp.asarray([[1.0, 1.0, 0.0, 0.0]])
    full = ce_loss(logits, targets)
    masked = ce_loss(logits, targets, mask)
    np.testing.assert_allclose(float(full), np.log(8), rtol=1e-6)
    np.testing.assert_allclose(float(masked), np.log(8), rtol=1e-6)


def test_grad_clip_bounds_update():
    cfg = AdamConfig(lr=1.0, grad_clip=1e-3, warmup_steps=1, weight_decay=0.0)
    params = {"w": jnp.ones((4,))}
    opt = adam_init(cfg, params)
    grads = {"w": jnp.full((4,), 100.0)}
    new_p, _, m = adam_update(cfg, params, grads, opt, jnp.int32(0))
    assert float(m["grad_norm"]) == pytest.approx(200.0)
    # clipped: the effective gradient has norm 1e-3 -> adam normalizes to ~lr
    assert np.all(np.isfinite(np.asarray(new_p["w"])))


def test_sgdm_kind():
    cfg = AdamConfig(kind="sgdm", lr=0.1, warmup_steps=1, weight_decay=0.0,
                     grad_clip=1e9)
    params = {"w": jnp.ones((3,))}
    opt = adam_init(cfg, params)
    assert "nu" not in opt
    grads = {"w": jnp.ones((3,))}
    new_p, new_opt, _ = adam_update(cfg, params, grads, opt, jnp.int32(0))
    # first step: mu = 0.9*0 + 0.1*g = 0.1g; p -= lr*mu
    np.testing.assert_allclose(np.asarray(new_p["w"]), 1.0 - 0.1 * 0.1,
                               rtol=1e-6)


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)

"""Persistent XLA compile cache: cross-process hits, opt-out, placement.

The cache is enabled on ``import repro.core`` (launch/compile_cache.py).
Cross-process behavior can only be observed from fresh interpreters, so
the hit test runs the same tiny solve in two subprocesses against a
private cache dir: the first populates it, the second must add nothing.
"""
import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

SOLVE_SNIPPET = """
from repro.core import solve, make_problem, mixing
from repro.data.synthetic import make_regression
data = make_regression(3, 6, 4, k=2, seed=0)
p = make_problem("ridge", data, mixing.ring_graph(3), lam=1e-2)
r = solve(p, "dsba", steps=4, record_every=2, alpha=0.1)
assert r.z.shape == (3, 4)
"""


def _run_child(cache_env):
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    env.pop("REPRO_NO_COMPILE_CACHE", None)
    env.pop("REPRO_COMPILE_CACHE_DIR", None)
    env.update(cache_env)
    proc = subprocess.run(
        [sys.executable, "-c", SOLVE_SNIPPET],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr


def _entries(cache_dir: Path) -> set[str]:
    if not cache_dir.exists():
        return set()
    return {p.name for p in cache_dir.rglob("*") if p.is_file()}


def test_second_process_hits_the_cache(tmp_path):
    cache = tmp_path / "xla_cache"
    env = {"REPRO_COMPILE_CACHE_DIR": str(cache)}
    _run_child(env)
    first = _entries(cache)
    assert first, "first process should populate the compile cache"
    _run_child(env)
    second = _entries(cache)
    # everything the second process compiled was served from disk
    assert second == first


def test_opt_out_env_disables_the_cache(tmp_path):
    cache = tmp_path / "xla_cache"
    _run_child({
        "REPRO_COMPILE_CACHE_DIR": str(cache),
        "REPRO_NO_COMPILE_CACHE": "1",
    })
    assert not _entries(cache)


def test_default_dir_is_repo_local_and_ignored():
    from repro.launch.compile_cache import default_cache_dir

    d = default_cache_dir()
    assert d == REPO / ".jax_compile_cache"
    assert ".jax_compile_cache" in (REPO / ".gitignore").read_text()
